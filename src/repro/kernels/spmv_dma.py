"""Pallas TPU kernel: BBCSR SpMV — PIUMA's "DMA gather + selective caching".

Mapping of the paper's SpMV optimizations onto the TPU memory hierarchy
(DESIGN.md §2):

* *selective caching*  — CSR values/indices stream sequentially through VMEM
  tiles (the "cache the matrix" decision); the dense vector is never
  replicated: exactly one `block_cols` slice is resident per column block.
* *DMA gather to SPAD* — the Pallas pipeline DMAs the vector block into VMEM
  (SPAD) while the previous tile computes (double buffering = the offload
  engine running "in the background").
* *8-byte access*      — HBM cannot do 8 B, so the fine-grained gather/scatter
  happens **inside VMEM** as one-hot MXU matmuls: gather = onehot(cols) @ x,
  scatter = contribᵀ @ onehot(rows). Irregularity is densified locally while
  the global structure stays sparse.

Grid: one step per tile, ordered by (row_block, col_block); output row blocks
are revisited only consecutively, so accumulation uses the standard
init-on-first-visit pattern driven by the host-precomputed `tile_init` flags
(scalar-prefetched, like the tile→block maps).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.graph import BBCSR

__all__ = ["spmv_bbcsr_kernel_call", "spmspv_bbcsr_kernel_call",
           "collapse_inactive_blocks"]


def collapse_inactive_blocks(tile_cb: jnp.ndarray,
                             tile_active: jnp.ndarray) -> jnp.ndarray:
    """x-block DMA schedule for SpMSpV: drop the fetch for inactive tiles.

    The Pallas pipeline issues a new x-block DMA whenever consecutive grid
    steps map to *different* block indices.  `pl.when` alone only skips the
    compute — the inactive tile's x block still streams into VMEM dead.  So
    the x index_map is collapsed: an inactive tile re-uses the most recent
    active tile's column block (same index => no new DMA), and tiles before
    the first active one pin block 0.  Works for any engine operand the
    active mask derives from — BFS frontiers and the structured-combine
    programs' weight operands alike (`engine.tile_active`).

    Returns the (n_tiles,) int32 schedule handed to the kernel as its cb
    scalar-prefetch operand (the kernel body itself never reads cb).
    """
    ta = tile_active.astype(jnp.int32)
    n = ta.shape[0]
    idx = jnp.where(ta == 1, jnp.arange(n, dtype=jnp.int32), -1)
    last_active = jax.lax.cummax(idx)
    safe = jnp.maximum(last_active, 0)
    return jnp.where(last_active >= 0, jnp.take(tile_cb, safe), 0).astype(jnp.int32)


def _tile_yblk(rows_ref, cols_ref, vals_ref, x_ref, *, block_rows: int,
               block_cols: int, tile_nnz: int):
    """One tile's dense output block: gather + scatter on the MXU."""
    cols = cols_ref[0, :]                                   # (T,) local col ids
    rows = rows_ref[0, :]                                   # (T,) local row ids
    vals = vals_ref[0, :]                                   # (T,) 0 on padding
    xblk = x_ref[0, :]                                      # (C,) VMEM-resident vector block

    # fine-grained gather inside VMEM, expressed on the MXU
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_nnz, block_cols), 1)
    onehot_g = (cols[:, None] == col_iota).astype(jnp.float32)      # (T, C)
    gathered = jax.lax.dot_general(
        onehot_g, xblk[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]                   # (T,)

    contrib = vals * gathered                                       # (T,)

    # fine-grained scatter-add inside VMEM, also on the MXU
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_nnz, block_rows), 1)
    onehot_s = (rows[:, None] == row_iota).astype(jnp.float32)      # (T, R)
    return jax.lax.dot_general(
        contrib[None, :], onehot_s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                        # (1, R)


def _kernel(rb_ref, cb_ref, init_ref, rows_ref, cols_ref, vals_ref, x_ref, y_ref,
            *, block_rows: int, block_cols: int, tile_nnz: int):
    i = pl.program_id(0)
    yblk = _tile_yblk(rows_ref, cols_ref, vals_ref, x_ref,
                      block_rows=block_rows, block_cols=block_cols,
                      tile_nnz=tile_nnz)

    @pl.when(init_ref[i] == 1)
    def _init():
        y_ref[0, :] = yblk[0]

    @pl.when(init_ref[i] == 0)
    def _acc():
        y_ref[0, :] += yblk[0]


def _spmspv_kernel(rb_ref, cb_ref, init_ref, act_ref, rows_ref, cols_ref,
                   vals_ref, x_ref, y_ref, *, block_rows: int, block_cols: int,
                   tile_nnz: int):
    """SpMSpV: the scalar-prefetched `act` flag marks tiles whose column block
    holds at least one active (nonzero) vector entry; inactive tiles skip the
    gather/compute entirely (work ∝ active columns, the direction-optimizing
    engine's sparse step) and only zero-initialize their output block."""
    i = pl.program_id(0)
    act = act_ref[i]

    @pl.when(jnp.logical_and(init_ref[i] == 1, act == 0))
    def _zero():
        y_ref[0, :] = jnp.zeros((block_rows,), jnp.float32)

    @pl.when(act == 1)
    def _compute():
        yblk = _tile_yblk(rows_ref, cols_ref, vals_ref, x_ref,
                          block_rows=block_rows, block_cols=block_cols,
                          tile_nnz=tile_nnz)

        @pl.when(init_ref[i] == 1)
        def _init():
            y_ref[0, :] = yblk[0]

        @pl.when(init_ref[i] == 0)
        def _acc():
            y_ref[0, :] += yblk[0]


def _tile_yblk_select(rows_ref, cols_ref, vals_ref, x_ref, cnt, *,
                      block_rows: int, block_cols: int, tile_nnz: int,
                      combine: str):
    """One tile's output block for the min/max combines, by masked select.

    The MXU one-hot matmuls only implement *additive* gather/scatter (0 * x
    annihilates, + accumulates) — and a one-hot gather of a vector holding
    the min-identity +inf would produce 0 * inf = NaN.  So the min/max tile
    combine stays on the VPU as two masked-select reductions:

    * gather:  sel[t, c] = x[c] where cols[t] == c else identity; row-min
      picks x[cols[t]] exactly (one live column per row).
    * relax:   contrib = gathered + vals — the (min,+)/(max,+) semirings'
      edge op; slots past ``cnt`` (padding is always a tile's tail) park at
      the identity (a padded (0, 0, 0.0) slot is otherwise indistinguishable
      from a real edge).
    * scatter: y[r] = reduce_t contrib[t] where rows[t] == r else identity —
      the same select pattern transposed.
    """
    cols = cols_ref[0, :]
    rows = rows_ref[0, :]
    vals = vals_ref[0, :]
    xblk = x_ref[0, :]
    ident = jnp.float32(jnp.inf if combine == "min" else -jnp.inf)
    red = jnp.min if combine == "min" else jnp.max

    col_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_nnz, block_cols), 1)
    sel = jnp.where(cols[:, None] == col_iota, xblk[None, :], ident)
    gathered = jnp.min(sel, axis=1) if combine == "min" else jnp.max(sel, axis=1)

    slot = jax.lax.broadcasted_iota(jnp.int32, (tile_nnz, 1), 0)[:, 0]
    contrib = jnp.where(slot < cnt, gathered + vals, ident)     # (T,)

    row_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_nnz, block_rows), 1)
    scat = jnp.where(rows[:, None] == row_iota, contrib[:, None], ident)
    return red(scat, axis=0)[None, :]                           # (1, R)


def _spmspv_select_kernel(rb_ref, cb_ref, init_ref, act_ref, cnt_ref,
                          rows_ref, cols_ref, vals_ref, x_ref, y_ref, *,
                          block_rows: int, block_cols: int, tile_nnz: int,
                          combine: str):
    """SpMSpV with a min/max destination combine: same tile schedule as the
    'add' kernel (inactive tiles skip compute and their x DMA is collapsed),
    but blocks initialize to the combine identity and revisits reduce with
    min/max instead of accumulating.  ``cnt_ref`` is the scalar-prefetched
    per-tile real-nonzero count (`BBCSR.tile_cnt`)."""
    i = pl.program_id(0)
    act = act_ref[i]
    ident = jnp.float32(jnp.inf if combine == "min" else -jnp.inf)

    @pl.when(jnp.logical_and(init_ref[i] == 1, act == 0))
    def _ident():
        y_ref[0, :] = jnp.full((block_rows,), ident, jnp.float32)

    @pl.when(act == 1)
    def _compute():
        yblk = _tile_yblk_select(rows_ref, cols_ref, vals_ref, x_ref,
                                 cnt_ref[i],
                                 block_rows=block_rows, block_cols=block_cols,
                                 tile_nnz=tile_nnz, combine=combine)

        @pl.when(init_ref[i] == 1)
        def _init():
            y_ref[0, :] = yblk[0]

        @pl.when(init_ref[i] == 0)
        def _acc():
            if combine == "min":
                y_ref[0, :] = jnp.minimum(y_ref[0, :], yblk[0])
            else:
                y_ref[0, :] = jnp.maximum(y_ref[0, :], yblk[0])


def spmv_bbcsr_kernel_call(bb: BBCSR, x: jnp.ndarray, *, interpret: bool = True
                           ) -> jnp.ndarray:
    """Launch the kernel. Returns y (n_rows,) float32."""
    n_rb, n_cb = bb.n_row_blocks, bb.n_col_blocks
    x_pad = jnp.pad(x.astype(jnp.float32), (0, n_cb * bb.block_cols - x.shape[0]))
    x2d = x_pad.reshape(n_cb, bb.block_cols)
    kern = functools.partial(_kernel, block_rows=bb.block_rows,
                             block_cols=bb.block_cols, tile_nnz=bb.tile_nnz)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # tile_rb, tile_cb, tile_init
        grid=(bb.n_tiles,),
        in_specs=[
            pl.BlockSpec((1, bb.tile_nnz), lambda i, rb, cb, ini: (i, 0)),  # rows
            pl.BlockSpec((1, bb.tile_nnz), lambda i, rb, cb, ini: (i, 0)),  # cols
            pl.BlockSpec((1, bb.tile_nnz), lambda i, rb, cb, ini: (i, 0)),  # vals
            pl.BlockSpec((1, bb.block_cols), lambda i, rb, cb, ini: (cb[i], 0)),  # x blk
        ],
        out_specs=pl.BlockSpec((1, bb.block_rows), lambda i, rb, cb, ini: (rb[i], 0)),
    )
    y2d = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rb, bb.block_rows), jnp.float32),
        interpret=interpret,
    )(bb.tile_rb, bb.tile_cb, bb.tile_init,
      bb.rows_local, bb.cols_local, bb.vals, x2d)
    return y2d.reshape(-1)[: bb.n_rows]


def spmspv_bbcsr_kernel_call(bb: BBCSR, x: jnp.ndarray,
                             tile_active: jnp.ndarray, *,
                             combine: str = "add",
                             interpret: bool = True) -> jnp.ndarray:
    """y = A ⊕ x for a sparsely-populated x, ⊕ per ``combine``.

    `tile_active` is (n_tiles,) int32 — 1 iff the tile's column block holds a
    nonzero x entry (see `engine.tile_active`).  Inactive tiles skip the
    compute (`pl.when`) *and* the x-block DMA (their index_map entry is
    collapsed onto the previous active tile's block via
    `collapse_inactive_blocks`), so both tile work and VMEM traffic scale
    with the active column blocks instead of nnz(A).

    combine='add' (default) is the MXU one-hot path computing val * x[col];
    'min'/'max' run the masked-select tile combine relaxing x[col] + val
    (the (min,+)/(max,+) distance semirings) — they need ``bb.tile_cnt`` and
    the caller's "active" convention flips to "x[col] != identity" (the
    engine's frontier mask covers both).  Untouched rows return the combine
    identity.
    """
    if combine not in ("add", "min", "max"):
        raise ValueError(f"combine must be 'add', 'min' or 'max', got {combine!r}")
    n_rb, n_cb = bb.n_row_blocks, bb.n_col_blocks
    pad_val = 0.0 if combine == "add" else float("inf") if combine == "min" \
        else float("-inf")
    x_pad = jnp.pad(x.astype(jnp.float32),
                    (0, n_cb * bb.block_cols - x.shape[0]),
                    constant_values=pad_val)
    x2d = x_pad.reshape(n_cb, bb.block_cols)
    cb_sched = collapse_inactive_blocks(bb.tile_cb, tile_active)
    if combine == "add":
        kern = functools.partial(_spmspv_kernel, block_rows=bb.block_rows,
                                 block_cols=bb.block_cols, tile_nnz=bb.tile_nnz)
        # tile_rb, tile_cb, tile_init, tile_active
        scalars = (bb.tile_rb, cb_sched, bb.tile_init,
                   tile_active.astype(jnp.int32))

        def tile_spec():
            return pl.BlockSpec((1, bb.tile_nnz),
                                lambda i, rb, cb, ini, act: (i, 0))

        x_spec = pl.BlockSpec((1, bb.block_cols),
                              lambda i, rb, cb, ini, act: (cb[i], 0))
        y_spec = pl.BlockSpec((1, bb.block_rows),
                              lambda i, rb, cb, ini, act: (rb[i], 0))
    else:
        if bb.tile_cnt is None:
            raise ValueError("min/max combines need the BBCSR per-tile "
                             "padding counts (mask) — rebuild the operand "
                             "with to_bbcsr")
        kern = functools.partial(_spmspv_select_kernel,
                                 block_rows=bb.block_rows,
                                 block_cols=bb.block_cols,
                                 tile_nnz=bb.tile_nnz, combine=combine)
        # ... + tile_cnt (the padding boundary per tile)
        scalars = (bb.tile_rb, cb_sched, bb.tile_init,
                   tile_active.astype(jnp.int32), bb.tile_cnt)

        def tile_spec():
            return pl.BlockSpec((1, bb.tile_nnz),
                                lambda i, rb, cb, ini, act, cnt: (i, 0))

        x_spec = pl.BlockSpec((1, bb.block_cols),
                              lambda i, rb, cb, ini, act, cnt: (cb[i], 0))
        y_spec = pl.BlockSpec((1, bb.block_rows),
                              lambda i, rb, cb, ini, act, cnt: (rb[i], 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalars),
        grid=(bb.n_tiles,),
        in_specs=[tile_spec() for _ in range(3)] + [x_spec],
        out_specs=y_spec,
    )
    y2d = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rb, bb.block_rows), jnp.float32),
        interpret=interpret,
    )(*scalars, bb.rows_local, bb.cols_local, bb.vals, x2d)
    return y2d.reshape(-1)[: bb.n_rows]
