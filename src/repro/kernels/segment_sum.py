"""Pallas TPU kernel: sorted segment-sum — the "remote atomic apply" stage.

`remote_scatter_add` (core/offload.py) routes (index, value) pairs to the
owner shard; the owner then applies one fused reduction.  This kernel is that
apply stage: data rows arrive *sorted by segment id* (the routing step sorts),
and the scatter is expressed as a one-hot MXU matmul per input block:

    out += onehot(seg_blk)^T @ data_blk        # (M, bn) @ (bn, d)

The output (num_segments, d) stays VMEM-resident across the grid (init at
step 0) — sized for the per-shard vertex partitions the offload engines
produce (ops.py falls back to jax.ops.segment_sum above the VMEM budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["segment_sum_kernel_call"]


def _kernel(seg_ref, data_ref, out_ref, *, block_n: int, num_segments: int):
    i = pl.program_id(0)
    seg = seg_ref[0, :]                                        # (bn,) sorted ids, -1 pad
    data = data_ref[...]                                       # (1, bn, d) -> use [0]
    seg_iota = jax.lax.broadcasted_iota(jnp.int32, (block_n, num_segments), 1)
    onehot = (seg[:, None] == seg_iota).astype(jnp.float32)    # (bn, M); -1 matches none
    blk = jax.lax.dot_general(
        onehot, data[0].astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (M, d)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = blk

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += blk


def segment_sum_kernel_call(data: jnp.ndarray, seg: jnp.ndarray, num_segments: int,
                            *, block_n: int = 512, interpret: bool = True) -> jnp.ndarray:
    """data (N, d) f32, seg (N,) int32 sorted ascending (-1 = drop). -> (M, d)."""
    n, d = data.shape
    n_pad = -(-n // block_n) * block_n
    data = jnp.pad(data.astype(jnp.float32), ((0, n_pad - n), (0, 0)))
    seg = jnp.pad(seg.astype(jnp.int32), (0, n_pad - n), constant_values=-1)
    kern = functools.partial(_kernel, block_n=block_n, num_segments=num_segments)
    grid = (n_pad // block_n,)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=0,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_n), lambda i: (i, 0)),
                pl.BlockSpec((1, block_n, d), lambda i: (i, 0, 0)),
            ],
            out_specs=pl.BlockSpec((num_segments, d), lambda i: (0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((num_segments, d), jnp.float32),
        interpret=interpret,
    )(seg.reshape(-1, block_n), data.reshape(-1, block_n, d))
    return out
