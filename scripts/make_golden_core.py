"""Capture the golden-equivalence grid for the ExecutionCore refactor.

Run ONCE against the PRE-refactor engine (PR 4 tree) to persist every public
runner's outputs across the (program family x lane representation x mode)
grid on fixed seeds:

    PYTHONPATH=src python scripts/make_golden_core.py

writes ``tests/golden/core_grid.npz``, which ``tests/test_execution_core.py``
replays bit-exactly against the refactored engine.  The grid deliberately
spans every lane representation (scalar, vmapped valued, bit-packed) and
every direction mode; the distributed placement is covered separately by the
partition-identity checks in ``tests/_distributed_main.py`` (goldens would
depend on the forced device count, so they gate there, not here).

Regenerating this file against a post-refactor engine would defeat its
purpose — only do so when a PR *deliberately* changes numerical behavior,
and say so in the PR.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, rmat, uniform_random_graph
from repro.core.algorithms import (auto_delta, bfs, connected_components,
                                   label_propagation, msbfs, ppr, ppr_batched,
                                   sssp, sssp_batched)

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "tests", "golden", "core_grid.npz")

SOURCES = np.array([0, 3, 17, 64, 0], dtype=np.int32)  # dup lane on purpose


def build_grid():
    g = rmat(7, 8, seed=11)          # the service test graph's shape class
    u = uniform_random_graph(150, 4, seed=5)
    d_g, d_u = auto_delta(g), auto_delta(u)
    out = {"meta_delta_g": np.float64(d_g), "meta_delta_u": np.float64(d_u)}
    for mode in ("push", "pull", "auto"):
        # scalar lanes, local placement
        out[f"bfs/scalar/{mode}"] = np.asarray(bfs(g, 0, mode=mode))
        out[f"sssp/scalar/{mode}"] = np.asarray(sssp(g, 0, delta=d_g,
                                                     mode=mode))
        out[f"cc/scalar/{mode}"] = np.asarray(
            connected_components(u, mode=mode))
        # packed boolean lanes (MS-BFS)
        out[f"bfs/packed/{mode}"] = np.asarray(msbfs(g, SOURCES, mode=mode))
        # vmapped valued lanes
        out[f"sssp/valued/{mode}"] = np.asarray(
            sssp_batched(g, SOURCES, delta=d_g, mode=mode))
    # dense-regime programs (mode is pull-only by construction)
    out["ppr/scalar/pull"] = np.asarray(ppr(g, 3, iters=12))
    out["ppr/valued/pull"] = np.asarray(ppr_batched(g, SOURCES, iters=12))
    # structured combine: argmax_weighted (weighted LPA)
    out["lpa/scalar/auto"] = np.asarray(label_propagation(g, iters=4))
    # structured combine: sample (keyed, so deterministic given the key)
    key = jax.random.PRNGKey(7)
    out["sample/scalar/push"] = np.asarray(engine.sample_neighbors(
        g, jnp.arange(64, dtype=jnp.int32), key))
    out["sample/scalar/weighted"] = np.asarray(engine.sample_neighbors(
        g, jnp.arange(64, dtype=jnp.int32), key, weighted=True))
    # stats trace: the refactor must preserve the direction decisions too
    _, st = sssp(g, 0, delta=d_g, return_stats=True)
    out["sssp/stats/auto"] = np.asarray(
        [int(st["iters"]), int(st["pushes"]), int(st["pulls"])])
    lv, st = msbfs(g, SOURCES, return_stats=True)
    out["msbfs/stats/auto"] = np.asarray(
        [int(st["iters"]), int(st["pushes"]), int(st["pulls"])])
    return out


if __name__ == "__main__":
    grid = build_grid()
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    np.savez_compressed(OUT, **grid)
    print(f"wrote {OUT} ({len(grid)} entries)")
    for k, v in sorted(grid.items()):
        print(f"  {k:24s} {v.shape} {v.dtype}")
