"""Capture the golden streaming-replay trace for DESIGN.md §16.

Run ONCE to persist a fixed RMAT graph's update stream and the FROM-SCRATCH
BFS/CC/SSSP results after every epoch:

    PYTHONPATH=src python scripts/make_golden_streaming.py

writes ``tests/golden/streaming.npz``, which ``tests/test_streaming.py``
replays through ``GraphHandle.apply`` + ``repair_or_recompute`` and checks
bit-exact agreement at every epoch — pinning both the overlay-splice CSR
semantics and the incremental-repair fixpoints across future refactors.

The stream is deliberately mixed: insert-only epochs (label-correcting
repair path), a weight-raising upsert epoch and a delete epoch (both must
take the logged full-recompute fallback).  Weights for the "safe" epochs
are drawn below the RMAT weight floor-ish (tiny constants) so upserts only
ever decrease — ``monotone_safe`` flags are recorded too, so the replay
asserts the dispatcher took the intended path.

Regenerating against a changed engine defeats the purpose — only do so when
a PR *deliberately* changes numerical behavior, and say so in the PR.
"""
import os

import numpy as np

from repro.core import GraphHandle, rmat
from repro.core.algorithms import (auto_delta, bfs, connected_components,
                                   repair_or_recompute, sssp)

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "tests", "golden", "streaming.npz")

SCALE, EDGE_FACTOR, SEED = 8, 8, 42
N_EPOCHS = 6
SOURCE = 0


def make_stream(n, rng):
    """Per-epoch (ins_r, ins_c, ins_v, del_r, del_c) batches."""
    stream = []
    for e in range(N_EPOCHS):
        k = int(rng.integers(8, 40))
        ins_r = rng.integers(0, n, k)
        ins_c = rng.integers(0, n, k)
        if e == 3:       # weight-raising upserts -> fallback epoch
            ins_v = rng.uniform(1.5, 2.0, k).astype(np.float32)
        else:            # below any plausible existing weight -> safe
            ins_v = rng.uniform(1e-4, 1e-3, k).astype(np.float32)
        if e == 4:       # delete epoch -> fallback
            d = int(rng.integers(4, 12))
            del_r = rng.integers(0, n, d)
            del_c = rng.integers(0, n, d)
        else:
            del_r = del_c = np.zeros(0, np.int64)
        stream.append((ins_r.astype(np.int64), ins_c.astype(np.int64), ins_v,
                       del_r.astype(np.int64), del_c.astype(np.int64)))
    return stream


def build():
    g = rmat(SCALE, EDGE_FACTOR, seed=SEED)
    n = g.n_rows
    rng = np.random.default_rng(7)
    stream = make_stream(n, rng)
    handle = GraphHandle.wrap(g, n_partitions=8)
    out = {"meta": np.asarray([SCALE, EDGE_FACTOR, SEED, N_EPOCHS, SOURCE],
                              np.int64)}
    prev = {"bfs": bfs(handle.csr, SOURCE),
            "cc": connected_components(handle.csr),
            "sssp": sssp(handle.csr, SOURCE, delta=auto_delta(handle.csr))}
    out["epoch0/bfs"] = np.asarray(prev["bfs"])
    out["epoch0/cc"] = np.asarray(prev["cc"])
    out["epoch0/sssp"] = np.asarray(prev["sssp"])
    for e, (ir, ic, iv, dr, dc) in enumerate(stream, start=1):
        out[f"epoch{e}/ins_r"], out[f"epoch{e}/ins_c"] = ir, ic
        out[f"epoch{e}/ins_v"] = iv
        out[f"epoch{e}/del_r"], out[f"epoch{e}/del_c"] = dr, dc
        handle, report = handle.apply((ir, ic, iv), (dr, dc))
        out[f"epoch{e}/monotone_safe"] = np.asarray([report.monotone_safe])
        # the golden values are FROM SCRATCH on the updated graph — the
        # replay goes through repair_or_recompute and must match bit-exactly
        csr = handle.csr
        out[f"epoch{e}/bfs"] = np.asarray(bfs(csr, SOURCE))
        out[f"epoch{e}/cc"] = np.asarray(connected_components(csr))
        out[f"epoch{e}/sssp"] = np.asarray(
            sssp(csr, SOURCE, delta=auto_delta(csr)))
        # sanity while generating: the repair path agrees already
        for kind in ("bfs", "cc", "sssp"):
            got = np.asarray(repair_or_recompute(kind, handle, prev[kind],
                                                 report, source=SOURCE))
            assert (got == out[f"epoch{e}/{kind}"]).all(), (e, kind)
            prev[kind] = got
    return out


if __name__ == "__main__":
    grid = build()
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    np.savez_compressed(OUT, **grid)
    print(f"wrote {OUT} ({len(grid)} entries)")
