#!/usr/bin/env python
"""CI guard: the engine must keep exactly ONE stepping loop.

PR 5 collapsed the engine's five public runners onto one ExecutionCore
stepping loop (`engine._core_loop`, DESIGN.md §14).  Copy-paste runners grow
back silently — a second `lax.while_loop` over (state, frontier) compiles
and passes output tests just fine — so the bench/fast lanes fail loudly
instead: this grep-level check needs no jax and runs in milliseconds.

Checked invariants over ``src/repro/core/engine.py``:
  * exactly one ``lax.while_loop(`` call (the core loop);
  * at most one ``lax.scan(`` call (run_queue's fixed-length body);
  * no ``fori_loop`` (a stepping loop in disguise);
  * all five public runners still exist and the frontier ones route through
    ``_core_loop`` / the shared wrappers.

Exit 0 = clean, 1 = violation (with a pointer at what regrew).
"""
import re
import sys
import os

ENGINE = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                      "src", "repro", "core", "engine.py")


def check(src: str):
    failures = []
    n_while = len(re.findall(r"lax\.while_loop\(", src))
    if n_while != 1:
        failures.append(
            f"engine.py holds {n_while} lax.while_loop calls (must be exactly "
            "1, inside _core_loop): a second stepping loop has regrown — fold "
            "it into the ExecutionCore grid instead (DESIGN.md §14)")
    n_scan = len(re.findall(r"lax\.scan\(", src))
    if n_scan > 1:
        failures.append(
            f"engine.py holds {n_scan} lax.scan calls (at most 1, run_queue's "
            "body): a scan-shaped stepping loop has regrown")
    if re.search(r"fori_loop\(", src):
        failures.append("engine.py calls fori_loop: that is a stepping loop "
                        "in disguise — use _core_loop")
    for runner in ("def run(", "def run_batched(", "def run_distributed(",
                   "def run_batched_distributed(", "def run_queue(",
                   "def _core_loop("):
        if runner not in src:
            failures.append(f"engine.py lost `{runner}...)`")
    # the frontier runners must delegate, not re-own, the loop
    for via in ("_run_local(", "_run_distributed(", "_core_loop(core"):
        if via not in src:
            failures.append(f"engine.py no longer routes through `{via}`")
    return failures


if __name__ == "__main__":
    src = open(ENGINE).read()
    failures = check(src)
    for f in failures:
        print(f"SINGLE-CORE GUARD: {f}", file=sys.stderr)
    print("single-core guard: " + ("FAIL" if failures else
                                   "OK (one stepping loop)"))
    sys.exit(1 if failures else 0)
