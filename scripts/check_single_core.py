#!/usr/bin/env python
"""CI guard: the engine must keep exactly ONE stepping loop.

PR 5 collapsed the engine's five public runners onto one ExecutionCore
stepping loop (`engine._core_loop`, DESIGN.md §14).  Copy-paste runners grow
back silently — a second `lax.while_loop` over (state, frontier) compiles
and passes output tests just fine — so the fast/bench lanes fail loudly
instead.

Since PR 6 the grep body is gone: this script is a thin CLI shim over the
AST `single-core` rule in ``repro.analysis`` (DESIGN.md §15), which counts
actual call nodes instead of strings — a commented-out ``lax.while_loop(``
no longer trips it, and an aliased loop no longer dodges it.  Same
contract as always: no jax import, milliseconds, exit 0 = clean,
1 = violation (with a pointer at what regrew).
"""
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.analysis import Analyzer  # noqa: E402
from repro.analysis.rules import SingleCoreRule  # noqa: E402

ENGINE = os.path.join(ROOT, "src", "repro", "core", "engine.py")


def check(src: str):
    """Findings for an engine source string (kept for test fixtures)."""
    return [f.format() for f in Analyzer([SingleCoreRule()]).run_source(
        src, "src/repro/core/engine.py")]


if __name__ == "__main__":
    failures = check(open(ENGINE).read())
    for f in failures:
        print(f"SINGLE-CORE GUARD: {f}", file=sys.stderr)
    print("single-core guard: " + ("FAIL" if failures else
                                   "OK (one stepping loop)"))
    sys.exit(1 if failures else 0)
