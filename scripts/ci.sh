#!/usr/bin/env bash
# CI entry point — four lanes, runnable singly or in sequence:
#
#   scripts/ci.sh lint        — repro-lint static analysis (DESIGN.md §15):
#                               python -m repro.analysis src tests.  Pure
#                               stdlib — no jax install needed — so it runs
#                               first and fails in seconds on a regrown
#                               stepping loop, a compat-boundary bypass, a
#                               host sync in traced code, an unbound
#                               shard_map collective, or an unhashable
#                               compile-cache key.
#   scripts/ci.sh fast        — pre-commit default: the single-stepping-loop
#                               guard (scripts/check_single_core.py, now a
#                               shim over the AST single-core rule), then
#                               the full suite minus the @slow
#                               subprocess-spawning distributed/dryrun tests.
#   scripts/ci.sh all         — tier-1: the full pytest suite (what the
#                               driver enforces; the PR gate).
#   scripts/ci.sh bench       — engine benchmark smoke lane: the guard, then
#                               bench_engine.py at tiny scale under 8 forced
#                               host devices (so the distributed multilevel
#                               AND distributed-service sections run; the
#                               query-service smoke — B ∈ {1,32,256} on
#                               RMAT-12 with the msbfs amortization gate and
#                               the deadline-miss-rate gate — always runs at
#                               its own fixed scale; since PR 10 the kernel
#                               lane gates tuned-vs-default per TUNED.json),
#                               writes ${BENCH_OUT:-BENCH_pr10.json} and
#                               fails on NaN / regression markers / >25%
#                               regression vs the newest committed
#                               BENCH_*.json.
#   scripts/ci.sh fast bench  — multiple lanes: each runs even if an earlier
#                               one failed; a per-lane summary is printed and
#                               the exit status is nonzero if ANY lane failed.
#
# .github/workflows/ci.yml maps these onto hosted CI: fast on push, all on
# pull requests, bench on both (uploading the BENCH json as an artifact).
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

run_lane() {
  case "$1" in
    lint)
      python -m repro.analysis src tests
      ;;
    fast)
      python scripts/check_single_core.py \
        && python -m pytest -x -q -m "not slow"
      ;;
    bench)
      python scripts/check_single_core.py \
        && XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
          python benchmarks/bench_engine.py --scale 7 --smoke \
            --json "${BENCH_OUT:-BENCH_pr10.json}" --baseline auto
      ;;
    all)
      python -m pytest -x -q
      ;;
    *)
      echo "usage: scripts/ci.sh [lint|fast|bench|all] ..." >&2
      return 2
      ;;
  esac
}

lanes=("${@:-all}")
declare -a results=()
status=0
for lane in "${lanes[@]}"; do
  echo "=== lane: $lane ==="
  if run_lane "$lane"; then
    results+=("$lane: PASS")
  else
    results+=("$lane: FAIL")
    status=1
  fi
done

echo
echo "=== lane summary ==="
for r in "${results[@]}"; do
  echo "  $r"
done
exit "$status"
