#!/usr/bin/env bash
# CI entry point.
#
#   scripts/ci.sh         — tier-1: the full suite (what the driver enforces)
#   scripts/ci.sh fast    — inner-loop subset: skips the @slow
#                           subprocess-spawning distributed/dryrun tests
#                           (~4 min), keeps everything else
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

case "${1:-all}" in
  fast)
    python -m pytest -x -q -m "not slow"
    ;;
  all)
    python -m pytest -x -q
    ;;
  *)
    echo "usage: scripts/ci.sh [fast|all]" >&2
    exit 2
    ;;
esac
