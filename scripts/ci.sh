#!/usr/bin/env bash
# CI entry point.
#
#   scripts/ci.sh         — tier-1: the full suite (what the driver enforces)
#   scripts/ci.sh fast    — pre-commit default: skips the @slow
#                           subprocess-spawning distributed/dryrun tests
#                           (~4 min), keeps everything else.  Run this before
#                           every commit; run the full suite before merge.
#   scripts/ci.sh bench   — engine benchmark smoke lane: bench_engine.py at
#                           tiny scale, fails on NaN / regression markers
#                           (mode disagreement, byte model not shrinking)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

case "${1:-all}" in
  fast)
    python -m pytest -x -q -m "not slow"
    ;;
  bench)
    python benchmarks/bench_engine.py --scale 7 --smoke
    ;;
  all)
    python -m pytest -x -q
    ;;
  *)
    echo "usage: scripts/ci.sh [fast|bench|all]" >&2
    exit 2
    ;;
esac
